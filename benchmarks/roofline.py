"""Roofline report: read the dry-run grid JSONL and emit the §Roofline table.

Per (arch x shape) on the single-pod mesh:
  - the three per-device roofline terms (seconds) + dominant bottleneck,
  - MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) on the same per-device
    token basis,
  - the MODEL_FLOPS / HLO_FLOPS ratio (useful-compute fraction; < 1 exposes
    remat recompute, attention overcompute, ragged-dot dense lowering...),
  - one-line "what would move the dominant term" note.

Usage: PYTHONPATH=src python -m benchmarks.roofline [results/dryrun_single.jsonl]
"""
from __future__ import annotations

import json
import sys

from repro.configs import INPUT_SHAPES, get_config

NOTES = {
    ("train", "memory"): "cut HBM traffic: vocab-parallel CE, leaner remat policy",
    ("train", "compute"): "raise MFU: larger per-client batch, fused attention kernel",
    ("train", "collective"): "overlap grad all-reduce with backward; shrink k/d",
    ("prefill", "memory"): "bigger kv-block tiles; avoid f32 logits materialization",
    ("prefill", "compute"): "attention-bound: flash kernel block sizes",
    ("prefill", "collective"): "sequence-shard activations to cut all-gathers",
    ("decode", "memory"): "weights+KV streaming bound (expected at batch*1 token); "
                          "quantize KV cache / wider batch",
    ("decode", "compute"): "decode should not be compute-bound: check dispatch",
    ("decode", "collective"): "TP all-reduce per token dominates: fuse/overlap",
}


def model_flops_per_device(arch: str, shape_name: str, n_devices: int,
                           kind: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / n_devices
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / n_devices
    # decode: ONE token per request
    return 2.0 * n * shape.global_batch / n_devices


def load(path: str):
    rows = []
    for line in open(path):
        d = json.loads(line)
        if d.get("status") == "ok" and "roofline" in d:
            rows.append(d)
    return rows


def table(rows) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " 6ND/HLO | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        r = d["roofline"]
        kind = INPUT_SHAPES[d["shape"]].kind
        mf = model_flops_per_device(d["arch"], d["shape"], d["n_devices"], kind)
        ratio = mf / r["flops"] if r["flops"] else float("nan")
        note = NOTES.get((kind, r["dominant"]), "")
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"**{r['dominant']}** | {ratio:.2f} | {note} |"
        )
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_single.jsonl"
    rows = load(path)
    print(f"# Roofline ({len(rows)} ok pairs from {path})\n")
    print(table(rows))


if __name__ == "__main__":
    main()
